"""jit'd public wrapper for the SSD chunk-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import ssd_scan


@partial(jax.jit, static_argnames=("chunk",))
def ssd(xh, dt, A, Bh, Ch, chunk: int = 256):
    """See kernel.ssd_scan.  Interpret mode off-TPU."""
    return ssd_scan(xh, dt, A, Bh, Ch, chunk,
                    interpret=jax.default_backend() != "tpu")
