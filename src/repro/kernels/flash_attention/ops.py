"""jit'd public wrapper for the flash-attention kernel.

On non-TPU backends the kernel runs in interpret mode (Python execution of
the kernel body — correctness only); on TPU it compiles via Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 128):
    """q: (B, Sq, G, R, hd); k, v: (B, Sk, G, hd) -> (B, Sq, G, R, hd)."""
    return flash_attention_fwd(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=not _on_tpu())
