"""Flash attention (causal, grouped GQA) as a Pallas TPU kernel.

TPU-native design (not a CUDA port — DESIGN.md §2):
  * grid (B, G, NQ, NK) with the KV axis innermost and *arbitrary*
    dimension semantics: the online-softmax state (m, l, acc) lives in
    VMEM scratch and is carried across NK grid steps;
  * q block (bq, R, hd) is flattened to (bq*R, hd) so the MXU sees a
    (bq*R, hd) x (hd, bk) matmul — R query heads per KV group ride along
    the sublane dim for free;
  * fully-masked causal blocks are skipped with @pl.when (real FLOP
    savings on TPU — the XLA fallback in models/layers.py can only mask);
  * block sizes default to 128/128: MXU-aligned (multiples of 128) and
    small enough that q, k, v, acc tiles fit VMEM comfortably
    (~(bq*R + 2*bk + bq*R)*hd*4B ≈ 5 MB at R=8, hd=128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from .._compat import CompilerParams as _CompilerParams


NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, causal: bool, scale: float,
                  n_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip blocks strictly above the diagonal
    run = (not causal) or (ki * bk < (qi + 1) * bq)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :, :]                       # (bq, R, hd)
        r, hd = q.shape[1], q.shape[2]
        qf = (q * scale).reshape(bq * r, hd)
        k = k_ref[0, :, 0, :]                          # (bk, hd)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq * r, bk), 0) // r
            kpos = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq * r, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        r = q_ref.shape[3]
        hd = q_ref.shape[4]
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :, :] = out.reshape(bq, r, hd).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, bq: int = 128,
                        bk: int = 128, interpret: bool = False):
    """q: (B, Sq, G, R, hd); k, v: (B, Sk, G, hd) -> (B, Sq, G, R, hd)."""
    b, sq, g, r, hd = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               scale=scale, n_k_blocks=nk)
    grid = (b, g, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, r, hd),
                         lambda bi, gi, qi, ki: (bi, qi, gi, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bi, gi, qi, ki: (bi, ki, gi, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bi, gi, qi, ki: (bi, ki, gi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, r, hd),
                               lambda bi, gi, qi, ki: (bi, qi, gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, g, r, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq * r, 1), jnp.float32),    # m
            pltpu.VMEM((bq * r, 1), jnp.float32),    # l
            pltpu.VMEM((bq * r, hd), jnp.float32),   # acc
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)
