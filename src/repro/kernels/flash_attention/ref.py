"""Pure-jnp oracle for the flash-attention kernel (grouped GQA layout)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q: (B, Sq, G, R, hd); k, v: (B, Sk, G, hd) -> (B, Sq, G, R, hd).

    Reference materializes the full score matrix — O(S^2) memory; fp32
    softmax.
    """
    b, sq, g, r, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqgrk,bsgk->bgrqs", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if causal:
        qpos = jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        scores = jnp.where((kpos <= qpos)[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqs,bsgk->bqgrk", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
