"""Transformer training as a registered workload over any Platform.

Until this layer existed the transformer DES app carried its own chip
and ICI constants (``TPU_V5E``, ``ICI``); now both backends are derived
from one ``Platform`` spec, exactly like HPL:

  * ``des_app(platform)``  — the per-rank DES
    (``core.apps.transformer.TransformerStepSim``) built via
    ``from_platform``: chip, ICI, MPI overhead, and the default mesh all
    come from the spec;
  * ``fastsim_model(platform)`` — batched ``stepsim.StepParams`` whose
    closed forms mirror the DES schedule, so model-size x mesh x
    platform what-if grids compile once (sweep-engine contract).

Both backends consume the SAME derived quantities — per-layer compute
seconds and ring wire bytes — computed once in ``_derive`` from the
model dims (Megatron-style tensor parallelism on the mesh's column axis,
data parallelism on rows, gradient ring across pods).  The backends
differ only in how they model the network, which is what DES-vs-stepsim
cross-validation (tests/test_workloads.py) pins down.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.apps.transformer import (LayerWork, StepWorkload,
                                         TransformerStepSim)

from .base import FastModel, Workload, WorkloadSpec, register_workload
from .stepsim import StepParams

# rendezvous per-message cost in the DES: MPI overhead + RDV handshake
# (2 half-RTTs) + wire base latency + one neighbor hop
_RDV_HALF_RTTS = 3.0

DEFAULTS = dict(
    num_layers=4, d_model=512, d_ff=2048, vocab=32768,
    seq_len=512, batch_per_replica=8,
    dtype_bytes=2, grad_bytes=4,       # bf16 activations, fp32 grads
    overlap=0.0,                       # 0 = the DES's serial schedule
)


def _ring_wire(nbytes: float, n: int) -> float:
    """Ring all-reduce wire bytes through one device (DES convention)."""
    return 2.0 * (n - 1) / n * nbytes if n > 1 else 0.0


@dataclasses.dataclass
class StepFastModel(FastModel):
    """Batched analytic step model; ``params`` variants (hardware or
    model-shape deltas alike) sweep as one compiled program."""
    params: StepParams
    tokens_per_step: float = 0.0       # global tokens per optimizer step

    @classmethod
    def sweep_models(cls, models: Sequence["StepFastModel"]) -> List[dict]:
        from .stepsim import sweep_step
        res = sweep_step([m.params for m in models])
        for m, r in zip(models, res):
            if m.tokens_per_step:
                r["tokens_per_s"] = m.tokens_per_step / r["time_s"]
        return res


@register_workload
class TransformerWorkload(Workload):
    kind = "transformer"

    @classmethod
    def default_spec(cls) -> WorkloadSpec:
        return WorkloadSpec.make(cls.kind, **DEFAULTS)

    # ------------------------------------------------------- geometry
    def geometry(self, platform) -> Tuple[Tuple[int, int], int]:
        """(rows, cols) mesh and pod count on ``platform``; the spec's
        ``mesh``/``pods`` params override the fabric-derived defaults
        (a k-D torus collapses to ``(prod(dims[:-1]), dims[-1])``)."""
        fab = platform.fabric
        if fab.kind not in ("torus", "multipod"):
            raise ValueError(
                f"transformer workload needs a torus or multipod fabric; "
                f"platform {platform.name!r} is {fab.kind!r}")
        p = self.spec.params_dict
        mesh = p.get("mesh")
        if mesh is None:
            mesh = (math.prod(fab.dims[:-1]), fab.dims[-1])
        if len(mesh) != 2:
            raise ValueError(f"mesh must be (rows, cols), got {mesh!r}")
        mesh = (int(mesh[0]), int(mesh[1]))
        pods = p.get("pods")
        if pods is None:
            pods = fab.n_pods if fab.kind == "multipod" else 1
        pods = int(pods)
        if mesh[0] < 1 or mesh[1] < 1 or pods < 1:
            raise ValueError(f"bad mesh {mesh} x {pods} pods")
        if pods > 1 and fab.kind != "multipod":
            raise ValueError(f"platform {platform.name!r} has one pod; "
                             f"spec asks for {pods}")
        return mesh, pods

    def validate(self, platform) -> None:
        mesh, pods = self.geometry(platform)
        need = mesh[0] * mesh[1] * pods
        have = platform.scale.n_ranks
        if need > have:
            raise ValueError(
                f"transformer workload needs {need} chips "
                f"({mesh[0]}x{mesh[1]} x {pods} pods) but platform "
                f"{platform.name!r} has {have}")
        if self.spec.get("num_layers", 1) < 1:
            raise ValueError("num_layers must be >= 1")

    def des_ranks(self, platform) -> int:
        mesh, pods = self.geometry(platform)
        return mesh[0] * mesh[1] * pods

    # ------------------------------------------------ shared derivation
    def _derive(self, platform) -> Dict:
        """The one place model dims meet the platform spec: everything
        both backends consume (compute seconds, wire bytes, effective
        bandwidths) is computed here so they can never diverge."""
        p = self.spec.params_dict
        (rows, cols), pods = self.geometry(platform)
        m, d = cols, rows                    # model / data group sizes
        node, fab, scale = platform.node, platform.fabric, platform.scale
        rpn = max(scale.ranks_per_node, 1)
        peak = node.peak_flops / rpn
        mem_bw = node.mem_bw / rpn

        L = int(p["num_layers"])
        D, F, V = float(p["d_model"]), float(p["d_ff"]), float(p["vocab"])
        S, B = float(p["seq_len"]), float(p["batch_per_replica"])
        dt, gb = float(p["dtype_bytes"]), float(p["grad_bytes"])
        t = S * B                            # tokens per replica per step

        p_layer = 4.0 * D * D + 2.0 * D * F  # weights per layer (floats)
        # fwd+bwd GEMM flops (6 per weight per token) + attention scores
        flops_chip = (6.0 * t * p_layer + 12.0 * B * S * S * D) / m
        act_bytes = t * D * dt               # one boundary activation
        # 3 weight passes (fwd, bwd, grad write) + activation traffic:
        # ~4 full-D boundary tensors and ~8 tensor-sharded internals
        bytes_chip = 3.0 * p_layer * dt / m + (4.0 + 8.0 / m) * act_bytes
        compute_s = max(flops_chip / (peak * node.gemm_efficiency),
                        bytes_chip / (mem_bw * node.mem_efficiency))

        # Megatron TP: 2 fwd + 2 bwd activation all-reduces per layer on
        # the model axis, folded into one ring per layer (DES and stepsim
        # both see one wire total, so round counts match)
        coll_model = 4.0 * _ring_wire(act_bytes, m)
        grads_chip = (L * p_layer + 2.0 * D * V) * gb / m
        coll_data = _ring_wire(grads_chip, d)

        phase_lat = (platform.mpi.overhead
                     + _RDV_HALF_RTTS * fab.base_latency + fab.hop_latency)
        n_pp = rows * cols
        # cross-pod ring: flows share the DCN (per-node bandwidth) and
        # funnel through the pod gateway, where dimension-order routing
        # concentrates ~half the pod's flows on one ingress ICI link
        pod_bw = min(fab.dcn_bw_per_node,
                     2.0 * fab.link_bw / max(n_pp, 2))
        pod_lat = (platform.mpi.overhead + _RDV_HALF_RTTS * fab.base_latency
                   + (rows + cols) / 2.0 * fab.hop_latency
                   + 2.0 * fab.dcn_latency)

        params = StepParams(
            peak_flops=peak, gemm_eff=node.gemm_efficiency,
            mem_bw=mem_bw, mem_eff=node.mem_efficiency,
            link_bw=fab.link_bw, phase_latency=phase_lat,
            pod_bw=pod_bw, pod_latency=pod_lat,
            flops_per_layer=flops_chip, bytes_per_layer=bytes_chip,
            coll_model_bytes=coll_model, coll_data_bytes=coll_data,
            n_layers=float(L), model_group=float(m), data_group=float(d),
            pod_group=float(pods), overlap=float(p.get("overlap", 0.0)))
        return dict(mesh=(rows, cols), pods=pods, compute_s=compute_s,
                    coll_model=coll_model, coll_data=coll_data,
                    params=params, n_layers=L,
                    tokens_per_step=t * d * pods)

    # ------------------------------------------------------- backends
    def step_workload(self, platform) -> StepWorkload:
        """The DES per-rank schedule derived from the spec pair."""
        d = self._derive(platform)
        layers = [LayerWork(d["compute_s"],
                            [("all-reduce", d["coll_model"], "model")]
                            if d["coll_model"] > 0 else [])
                  for _ in range(d["n_layers"])]
        tail = [("all-reduce", d["coll_data"], "data")] \
            if d["coll_data"] > 0 else []
        return StepWorkload(layers=layers, tail_collectives=tail)

    def des_app(self, platform, *, trace: bool = False, faults=None,
                regions=None, **kw):
        self.validate(platform)
        d = self._derive(platform)

        def build(workload, layer_marks=None):
            return TransformerStepSim.from_platform(
                workload, platform, mesh=d["mesh"], pods=d["pods"],
                trace=trace, faults=faults, layer_marks=layer_marks, **kw)

        if regions is None:
            return build(self.step_workload(platform))
        # representative region: the first `regions` layers run on the
        # exact DES (with the full-L tail collectives — their wire bytes
        # scale with the total layer count); the rest replicate the
        # steady-state per-layer delta
        from repro.scale import RegionStepSim
        return RegionStepSim(self.step_workload(platform), regions, build)

    def fastsim_model(self, platform, *, faults=None) -> StepFastModel:
        self.validate(platform)
        d = self._derive(platform)
        params = d["params"]
        if faults is not None:
            from repro.faults.fastsim import apply_faults
            params = apply_faults(params, faults)
        return StepFastModel(params=params,
                             tokens_per_step=d["tokens_per_step"])

    def predict_des(self, platform, *, trace: bool = False,
                    faults=None, regions=None) -> dict:
        app = self.des_app(platform, trace=trace, faults=faults,
                           regions=regions)
        res = app.run()
        d = self._derive(platform)
        out = {"time_s": res["step_s"], "step_s": res["step_s"],
               "events": res["events"],
               "tokens_per_s": d["tokens_per_step"] / res["step_s"]}
        if res.get("failed"):
            out["failed"] = True
            out["n_finished"] = res["n_finished"]
        if res.get("region_approx"):
            out["region_approx"] = True
            out["layers_simulated"] = res["layers_simulated"]
        if trace and app.trace.enabled:
            out["breakdown"] = app.trace.summary()
            if res.get("region_approx"):
                out["breakdown"]["region_approx"] = True
        return out
