"""The workload layer: one App abstraction drives every application the
framework can predict, over any ``Platform`` (DESIGN.md §15).

The paper's claim is that functional-level simulation generalizes beyond
HPL to full HPC applications; this module is where that generality
lives.  A ``Workload`` binds an application's scenario knobs (its
``WorkloadSpec``) to the two simulation backends every app must offer:

  * ``des_app(platform)``      — the discrete-event application (per-rank
    virtual threads issuing flows; contention is emergent), built from
    the platform spec;
  * ``fastsim_model(platform)``— a ``FastModel``: a traced-pytree
    parameter set plus batched sweep entry points, so scenario grids
    compile once (DESIGN.md §11's sweep engine, per workload).

``WorkloadSpec`` is frozen, hashable data (JSON round-trip) so a
scenario can be shipped to the serving layer, diffed, and versioned
exactly like a ``Platform``.  The registry maps workload kind names
("hpl", "transformer", ...) to classes; ``get_workload("hpl", N=4096)``
is the one call site every benchmark, example, and service goes
through.
"""
from __future__ import annotations

import abc
import dataclasses
import difflib
import json
from typing import (Any, Callable, ClassVar, Dict, List, Optional,
                    Sequence, Tuple, Type)

_JSON_SCALARS = (str, int, float, bool, type(None))


def _freeze(v):
    """Normalize a JSON-safe value for the frozen params table (lists
    become tuples so specs stay hashable)."""
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, _JSON_SCALARS):
        return v
    raise TypeError(f"WorkloadSpec params must be JSON-safe scalars or "
                    f"lists, got {type(v).__name__}: {v!r}")


def _thaw(v):
    if isinstance(v, tuple):
        return [_thaw(x) for x in v]
    return v


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One application scenario as data: the workload ``kind`` (registry
    key) plus its knob table.  The params table is normalized (sorted,
    tuples for sequences) so equal scenarios compare and hash equal and
    round-trip through JSON exactly."""
    kind: str
    params: Tuple[Tuple[str, Any], ...] = ()
    name: str = ""

    def __post_init__(self):
        object.__setattr__(
            self, "params",
            tuple(sorted((str(k), _freeze(v)) for k, v in self.params)))

    @classmethod
    def make(cls, kind: str, name: str = "", **params) -> "WorkloadSpec":
        return cls(kind=kind, name=name, params=tuple(params.items()))

    @property
    def params_dict(self) -> Dict[str, Any]:
        return dict(self.params)

    def get(self, key: str, default=None):
        return self.params_dict.get(key, default)

    def replace(self, **over) -> "WorkloadSpec":
        merged = dict(self.params)
        merged.update(over)
        return WorkloadSpec(kind=self.kind, params=tuple(merged.items()),
                            name=self.name)

    # -------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name,
                "params": [[k, _thaw(v)] for k, v in self.params]}

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "WorkloadSpec":
        return cls(kind=d["kind"], name=d.get("name", ""),
                   params=tuple((k, v) for k, v in d.get("params", [])))

    @classmethod
    def from_json(cls, s: str) -> "WorkloadSpec":
        return cls.from_dict(json.loads(s))


class FastModel(abc.ABC):
    """A workload's vectorized-simulator surface: ``params`` is a traced
    pytree (a frozen dataclass registered with jax), so hardware what-ifs
    are ``dataclasses.replace`` away and never recompile; ``sweep`` runs
    a params grid as one batched program.  ``sweep_models`` batches
    *across* scenarios of the same workload family — the serving layer's
    wave dispatch."""

    params: Any

    def sweep(self, params_list: Sequence[Any]) -> List[dict]:
        """One batched program over params variants of this scenario."""
        return type(self).sweep_models(
            [dataclasses.replace(self, params=p) for p in params_list])

    def predict(self, params=None) -> dict:
        return self.sweep([self.params if params is None else params])[0]

    @classmethod
    @abc.abstractmethod
    def sweep_models(cls, models: Sequence["FastModel"]) -> List[dict]:
        """Batch heterogeneous scenarios of this family in one sweep."""


class Workload(abc.ABC):
    """One application the framework can predict.  Subclasses set
    ``kind``, register with ``@register_workload``, and implement the
    three backend hooks; construction takes a spec and/or param
    overrides: ``HPLWorkload(N=4096, nb=128)``."""

    kind: ClassVar[str] = ""

    def __init__(self, spec: Optional[WorkloadSpec] = None, **params):
        base = spec if spec is not None else self.default_spec()
        if base.kind != self.kind:
            raise ValueError(f"{type(self).__name__} got a spec of kind "
                             f"{base.kind!r} (expected {self.kind!r})")
        if params:
            base = base.replace(**params)
        self.spec = base

    @classmethod
    def default_spec(cls) -> WorkloadSpec:
        return WorkloadSpec(kind=cls.kind)

    # ------------------------------------------------- backend hooks
    @abc.abstractmethod
    def validate(self, platform) -> None:
        """Raise ValueError when the scenario cannot run on ``platform``
        (capacity, fabric kind, missing defaults)."""

    @abc.abstractmethod
    def des_app(self, platform, *, trace: bool = False, faults=None,
                regions=None):
        """The discrete-event application, built from the platform spec;
        the returned object has ``.run()`` and (traced) ``.trace``.
        ``faults`` is an optional ``repro.faults.FaultSpec`` (or dict /
        JSON form) injected into the run — every fault kind is
        supported on this path.  ``regions`` (an int region length or a
        ``repro.scale.RegionSpec``) switches to representative-region
        simulation: one region of the iteration space runs on the exact
        DES and the rest is replicated analytically, with results
        stamped ``region_approx``."""

    @abc.abstractmethod
    def fastsim_model(self, platform, *, faults=None) -> FastModel:
        """The vectorized-simulator surface for this scenario.  A
        ``faults`` scenario is folded into the traced params
        (``repro.faults.fastsim.apply_faults``) — straggler/bandwidth
        kinds only; fail-stop raises (DES-only)."""

    def des_ranks(self, platform) -> int:
        """How many DES ranks ``des_app`` would spawn (serving guard)."""
        raise NotImplementedError

    # ------------------------------------------------- conveniences
    def predict(self, platform, *, faults=None) -> dict:
        """Fast prediction of this scenario on ``platform``, optionally
        under a degraded-platform ``faults`` scenario."""
        self.validate(platform)
        return self.fastsim_model(platform, faults=faults).predict()

    @abc.abstractmethod
    def predict_des(self, platform, *, trace: bool = False,
                    faults=None, regions=None) -> dict:
        """Full-DES prediction; with ``trace=True`` the result carries a
        ``breakdown`` (per-phase trace summary).  ``faults`` injects a
        degraded-platform scenario (all kinds; fail-stop runs report
        ``failed=True``).  ``regions`` requests representative-region
        simulation (see ``des_app``); region results carry
        ``region_approx=True``."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec.params_dict})"


# ------------------------------------------------------------- registry
_WORKLOADS: Dict[str, Type[Workload]] = {}


def register_workload(cls: Type[Workload]) -> Type[Workload]:
    if not cls.kind:
        raise ValueError(f"{cls.__name__} must set a non-empty kind")
    if cls.kind in _WORKLOADS and _WORKLOADS[cls.kind] is not cls:
        raise ValueError(f"workload kind {cls.kind!r} already registered "
                         f"by {_WORKLOADS[cls.kind].__name__}")
    _WORKLOADS[cls.kind] = cls
    return cls


def get_workload(name: str, spec: Optional[WorkloadSpec] = None,
                 **params) -> Workload:
    """Instantiate a registered workload by kind name, optionally from a
    spec and/or with param overrides."""
    try:
        cls = _WORKLOADS[name]
    except KeyError:
        close = difflib.get_close_matches(name, _WORKLOADS, n=3, cutoff=0.5)
        hint = (f"did you mean: {', '.join(close)}?" if close
                else f"registered: {', '.join(sorted(_WORKLOADS))}")
        raise KeyError(f"unknown workload {name!r}; {hint}") from None
    return cls(spec=spec, **params)


def workload_from_spec(spec: WorkloadSpec) -> Workload:
    return get_workload(spec.kind, spec=spec)


def list_workloads() -> List[str]:
    return sorted(_WORKLOADS)
