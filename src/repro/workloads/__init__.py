"""Workload layer: one App abstraction drives HPL *and* transformer
training over any Platform (DESIGN.md §15).

    from repro.workloads import get_workload
    from repro.platforms import get_platform

    plat = get_platform("tpu-v5e-pod")
    get_workload("hpl").predict(plat)              # HPL Rmax run
    get_workload("transformer").predict(plat)      # LM train-step time

Every workload offers the same two backends built from the same spec —
``des_app(platform)`` (discrete-event, contention emergent) and
``fastsim_model(platform)`` (traced-pytree batched sweeps) — and a
JSON-round-trip ``WorkloadSpec`` so scenarios are data, exactly like
``Platform`` specs.
"""
from .base import (FastModel, Workload, WorkloadSpec, get_workload,
                   list_workloads, register_workload, workload_from_spec)
from .hpl import HPLFastModel, HPLWorkload
from .stepsim import (StepParams, simulate_step_fast, step_time_traced,
                      sweep_step, trace_count)
from .transformer import StepFastModel, TransformerWorkload

__all__ = [
    "FastModel", "Workload", "WorkloadSpec", "get_workload",
    "list_workloads", "register_workload", "workload_from_spec",
    "HPLFastModel", "HPLWorkload",
    "StepParams", "simulate_step_fast", "step_time_traced", "sweep_step",
    "trace_count",
    "StepFastModel", "TransformerWorkload",
]
