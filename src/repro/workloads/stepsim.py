"""stepsim — the transformer train step as a batched JAX program.

The fastsim idea (DESIGN.md §10-11) applied to the second application:
where fastsim vectorizes HPL's panel recurrence, this module vectorizes
the train-step schedule the DES app (core/apps/transformer.py) walks
event by event — per-layer roofline compute, ring collectives on the
model axis, a tail gradient ring on the data axis, and a cross-pod DCN
ring when the job spans pods.

``StepParams`` is a frozen dataclass registered as a pytree: every leaf
is *traced*, so model-size x mesh x platform what-if grids never
recompile — ``sweep_step`` pads the scenario batch to a power of two and
runs it as ONE compiled program with a leading batch axis, exactly the
sweep-engine contract ``sweep_hpl`` gives HPL.  ``jax.grad`` flows
through ``step_time_traced`` for calibration parity with
``calibrate.fit_fastsim_params``.

The closed forms mirror the DES timing model, not an idealized one:
ring rounds serialize at ``per_round/bw + phase_latency`` where
``phase_latency`` is the DES's per-message cost (MPI overhead +
rendezvous handshakes + hop latency), so DES-vs-stepsim
cross-validation holds the same way DES-vs-fastsim does for HPL.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.fastsim import _pad_pow2, _record_shard, _shard_lanes
from repro.obs.metrics import RATIO_BUCKETS, get_global_metrics


@dataclasses.dataclass(frozen=True)
class StepParams:
    """One train-step scenario; every field is a traced pytree leaf.

    Group sizes are floats so the whole scenario — including the mesh —
    can ride the batch axis; bytes fields follow the DES wire convention
    (bytes moved through one device over the whole ring).
    """
    # chip (per rank)
    peak_flops: float
    gemm_eff: float
    mem_bw: float
    mem_eff: float
    # fabric
    link_bw: float               # B/s per ICI link per direction
    phase_latency: float         # per ring-round message cost (s)
    pod_bw: float = 25e9         # effective per-flow cross-pod B/s
    pod_latency: float = 10e-6   # per cross-pod round latency (s)
    # per-chip workload (derived from the model dims by the workload)
    flops_per_layer: float = 0.0
    bytes_per_layer: float = 0.0
    coll_model_bytes: float = 0.0   # ring wire bytes per layer, model axis
    coll_data_bytes: float = 0.0    # tail ring wire bytes, data axis
    n_layers: float = 1.0
    model_group: float = 1.0
    data_group: float = 1.0
    pod_group: float = 1.0
    overlap: float = 0.0         # fraction of comm hidden under compute


_STEP_FIELDS = tuple(f.name for f in dataclasses.fields(StepParams))

jax.tree_util.register_dataclass(
    StepParams, data_fields=list(_STEP_FIELDS), meta_fields=[])


def _f64_step_params(p: StepParams) -> StepParams:
    return StepParams(**{n: float(getattr(p, n)) for n in _STEP_FIELDS})


def _ring(wire_bytes, group, bw, latency):
    """Ring-collective time under the DES schedule: the wire bytes
    stream at the link rate while 2(n-1) rounds each pay the per-message
    latency; groups of one collapse to zero."""
    rounds = 2.0 * (group - 1.0)
    t = wire_bytes / bw + rounds * latency
    return jnp.where(group > 1.0, t, 0.0)


def _step_core(p: StepParams):
    """Traced step time; all leaves scalar or (B,)-batched."""
    compute = jnp.maximum(
        p.flops_per_layer / (p.peak_flops * p.gemm_eff),
        p.bytes_per_layer / (p.mem_bw * p.mem_eff))
    coll = _ring(p.coll_model_bytes, p.model_group, p.link_bw,
                 p.phase_latency)
    # overlap=0 reproduces the DES's serial schedule; >0 models async
    # collectives hidden under compute (the SimXLA overlap knob)
    layer = jnp.maximum(compute, coll) \
        + (1.0 - p.overlap) * jnp.minimum(compute, coll)
    tail = _ring(p.coll_data_bytes, p.data_group, p.link_bw,
                 p.phase_latency)
    # cross-pod ring: the DES rings wire/data_group bytes over the pod
    # group through the pod gateways
    pod_wire = p.coll_data_bytes / jnp.maximum(p.data_group, 1.0)
    pod = _ring(pod_wire, p.pod_group, p.pod_bw, p.pod_latency)
    return p.n_layers * layer + tail + pod


# --------------------------------------------------------- compile cache
_TRACE_COUNT = 0


def trace_count() -> int:
    """How many times the step core has been (re)traced — compile-once
    assertions for tests and benchmarks (mirrors fastsim.trace_count)."""
    return _TRACE_COUNT


@functools.lru_cache(maxsize=4)
def _compiled():
    def fn(p):
        global _TRACE_COUNT
        _TRACE_COUNT += 1
        return _step_core(p)
    return jax.jit(fn)


def step_time_traced(p: StepParams):
    """Differentiable scalar step time for traced ``p`` leaves (call
    under ``jax.experimental.enable_x64``) — the autodiff surface for
    gradient calibration of step parameters."""
    return _step_core(p)


def _stack_step_params(prm_list: Sequence[StepParams],
                       lanes: Sequence[int]) -> StepParams:
    return StepParams(**{
        n: np.asarray([float(getattr(prm_list[i], n)) for i in lanes],
                      np.float64)
        for n in _STEP_FIELDS})


def _result(p: StepParams, t: float) -> Dict:
    flops = p.n_layers * p.flops_per_layer
    return {"time_s": t, "step_s": t,
            "mfu": flops / max(t, 1e-30) / p.peak_flops}


def sweep_step(params_list: Sequence[StepParams]) -> List[Dict]:
    """Run a step-scenario sweep as one compiled batched program.

    The batch is padded to a power of two so repeat sweeps of any size
    reuse the compile cache; results come back in input order as dicts
    with ``time_s``/``step_s``/``mfu`` (model-level fields like
    tokens/s are layered on by ``TransformerWorkload``).
    """
    prm_list = [_f64_step_params(p) for p in params_list]
    if not prm_list:
        return []
    lanes = _pad_pow2(list(range(len(prm_list))))
    m = get_global_metrics()
    with enable_x64(True):
        fn = _compiled()
        (stacked,), sharded = _shard_lanes(
            len(lanes), _stack_step_params(prm_list, lanes))
        if m.enabled:
            pre, t0 = trace_count(), time.perf_counter()
        out = np.asarray(fn(stacked))
        if m.enabled:
            # same taxonomy as fastsim._record_dispatch, one shared
            # "step" bucket (the step core is shape-monomorphic)
            dt = time.perf_counter() - t0
            misses = trace_count() - pre
            if misses:
                m.counter("stepsim.compile_misses", bucket="step").inc(
                    misses)
                m.histogram("stepsim.compile_wall_s",
                            bucket="step").observe(dt)
            else:
                m.counter("stepsim.compile_hits", bucket="step").inc()
                m.histogram("stepsim.dispatch_wall_s").observe(dt)
            m.counter("stepsim.lanes_live").inc(len(prm_list))
            m.counter("stepsim.lanes_padded").inc(
                len(lanes) - len(prm_list))
            m.histogram("stepsim.sweep_occupancy", RATIO_BUCKETS).observe(
                len(prm_list) / len(lanes))
            _record_shard(m, sharded, prefix="stepsim")
    return [_result(p, float(t))
            for p, t in zip(prm_list, out[:len(prm_list)])]


def simulate_step_fast(p: StepParams) -> Dict:
    """Single-scenario convenience over ``sweep_step``."""
    return sweep_step([p])[0]
