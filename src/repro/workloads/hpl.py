"""HPL as a registered workload — the paper's application, extracted
from the HPL-specific plumbing into the generic layer.

The spec's params are the ``HPLConfig`` knobs; any of ``N``/``nb``/
``P``/``Q`` left unset (or 0) falls back to the platform's published run
geometry (``platform.hpl_config()``), so ``get_workload("hpl")`` with no
arguments predicts every registry machine's own Rmax run.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.apps.hpl import HPLConfig, HPLSim

from .base import (FastModel, Workload, WorkloadSpec, register_workload)

_CFG_KEYS = ("N", "nb", "P", "Q")


@dataclasses.dataclass
class HPLFastModel(FastModel):
    """The batched HPL recurrence bound to one run geometry: ``params``
    variants sweep as one compiled program (``fastsim.sweep_hpl``)."""
    cfg: HPLConfig
    params: object                     # FastSimParams

    @classmethod
    def sweep_models(cls, models: Sequence["HPLFastModel"]) -> List[dict]:
        """One compiled program per wave: scenarios sharing a shape
        bucket take ``sweep_hpl``'s grouped fast path; a wave that
        mixes buckets (a campaign grid over heterogeneous platforms)
        is forced into one shared bucket instead — the TOP500 fleet
        trick, so the family costs one dispatch either way."""
        from repro.core.fastsim import bucket_key, sweep_hpl
        cfgs = [m.cfg for m in models]
        prms = [m.params for m in models]
        if len({bucket_key(c) for c in cfgs}) > 1:
            bucket = (max(c.n_panels for c in cfgs),
                      max(c.P for c in cfgs),
                      max(c.Q for c in cfgs))
            return sweep_hpl(cfgs, prms, bucket=bucket)
        return sweep_hpl(cfgs, prms)


@register_workload
class HPLWorkload(Workload):
    kind = "hpl"

    def config(self, platform) -> HPLConfig:
        """The scenario's ``HPLConfig`` on ``platform`` (spec overrides
        win over the platform's published run geometry)."""
        p = self.spec.params_dict
        kw = {k: int(p[k]) for k in _CFG_KEYS if p.get(k)}
        if p.get("bcast"):
            kw["bcast"] = p["bcast"]
        if "lookahead" in p:
            kw["lookahead"] = int(p["lookahead"])
        return platform.hpl_config(**kw)

    def validate(self, platform) -> None:
        cfg = self.config(platform)     # raises on missing defaults
        if cfg.n_ranks > platform.scale.n_ranks:
            raise ValueError(
                f"hpl workload needs {cfg.n_ranks} ranks but platform "
                f"{platform.name!r} has {platform.scale.n_ranks}")

    def des_app(self, platform, *, trace: bool = False,
                faults=None, regions=None):
        if regions is None:
            return HPLSim(self.config(platform), platform, trace=trace,
                          faults=faults)
        from repro.scale import RegionHPLSim
        return RegionHPLSim(self.config(platform), platform,
                            region=regions, trace=trace, faults=faults)

    def des_ranks(self, platform) -> int:
        return self.config(platform).n_ranks

    def fastsim_model(self, platform, *, faults=None) -> HPLFastModel:
        cfg = self.config(platform)
        params = platform.fastsim()
        if faults is not None:
            from repro.faults.fastsim import apply_faults
            params = apply_faults(params, faults, grid=(cfg.P, cfg.Q))
        return HPLFastModel(cfg=cfg, params=params)

    def predict_des(self, platform, *, trace: bool = False,
                    faults=None, regions=None) -> dict:
        res = self.des_app(platform, trace=trace, faults=faults,
                           regions=regions).run()
        out = {"time_s": res.time_s, "gflops": res.gflops,
               "tflops": res.gflops / 1e3, "events": res.events}
        if res.failed:
            out["failed"] = True
            out["n_finished"] = res.n_finished
        if res.region_approx:
            out["region_approx"] = True
            out["panels_simulated"] = res.region_panels
        if trace and res.trace is not None:
            out["breakdown"] = res.trace.summary()
            if res.region_approx:
                out["breakdown"]["region_approx"] = True
        return out
