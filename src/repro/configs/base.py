"""Configuration system for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; every assigned
input shape as a ``ShapeConfig``.  Cluster/HPL-side configs (the paper's own
case study) live in ``clusters.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk_size: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rms"               # rms | ln
    act: str = "swiglu"             # swiglu | gelu
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): shared attention+mlp block applied every N ssm layers
    hybrid_period: int = 0
    # encoder-decoder (whisper-style)
    num_encoder_layers: int = 0
    encoder_seq: int = 0            # frames after the (stubbed) conv frontend
    # vlm (llava-style): precomputed image-patch embeddings prepended to text
    n_image_tokens: int = 0
    # whether full O(S^2) attention is the only sequence mixer (drives long_500k skip)
    attention_free: bool = False
    # optimizer override for memory-constrained giants (see DESIGN.md §6)
    optimizer: str = "adamw"        # adamw | adafactor
    remat: str = "full"             # full | none | dots
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # ---- performance knobs (EXPERIMENTS.md §Perf hillclimb) ----
    moe_impl: str = "einsum"        # einsum | scatter (sorted grouped-GEMM)
    attn_block: int = 1024          # blockwise-attention KV block
    force_scheme: Optional[str] = None   # override tp/sp scheme selection

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding tables are padded to a multiple of 256 so the vocab dim
        shards evenly on any production mesh axis combination; logits in the
        pad region are masked to -inf before the softmax."""
        return ((self.vocab_size + 255) // 256) * 256

    def n_params(self) -> int:
        """Total parameter count (analytical; used for 6ND model flops)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d
        out = 0 if self.tie_embeddings else self.vocab_size * d
        per_attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.qkv_bias:
            per_attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.act == "swiglu":
            per_mlp = 3 * d * self.d_ff
        else:
            per_mlp = 2 * d * self.d_ff
        per_moe = 0
        if self.moe is not None:
            e = self.moe
            per_exp = 3 * d * e.d_ff_expert if self.act == "swiglu" else 2 * d * e.d_ff_expert
            per_moe = e.num_experts * per_exp + d * e.num_experts
            per_mlp = 0
        per_ssm = 0
        if self.ssm is not None:
            s = self.ssm
            din, nh, ns = s.d_inner(d), s.n_heads(d), s.d_state
            # in_proj: z, x, B, C, dt ; out_proj ; conv ; A, D, dt_bias ; gated norm
            per_ssm = d * (2 * din + 2 * s.n_groups * ns + nh) + din * d
            per_ssm += s.d_conv * (din + 2 * s.n_groups * ns) + 3 * nh + din
        norms = 2 * d  # final norm + small terms folded in
        if self.family in ("ssm",):
            per_layer = per_ssm + d
            return emb + out + self.num_layers * per_layer + norms
        if self.family == "hybrid":
            per_layer = per_ssm + d
            shared = per_attn + per_mlp + 2 * d
            n_apps = self.num_layers // max(self.hybrid_period, 1)
            return emb + out + self.num_layers * per_layer + shared + norms
        per_layer = per_attn + (per_moe or per_mlp) + 2 * d
        n_dec = self.num_layers
        total = emb + out + n_dec * per_layer + norms
        if self.num_encoder_layers:
            enc_layer = per_attn + per_mlp + 2 * d
            cross = per_attn + d
            total += self.num_encoder_layers * enc_layer + n_dec * cross
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        per_exp = 3 * self.d_model * e.d_ff_expert if self.act == "swiglu" \
            else 2 * self.d_model * e.d_ff_expert
        inactive = (e.num_experts - e.top_k - e.n_shared_experts) * per_exp
        return self.n_params() - self.num_layers * inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k":
        return cfg.family in ("ssm", "hybrid")
    return True


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small: dict = dict(
        num_layers=min(cfg.num_layers, 2 if cfg.family != "hybrid" else 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(num_experts=4, top_k=min(cfg.moe.top_k, 2),
                                 d_ff_expert=128,
                                 capacity_factor=cfg.moe.capacity_factor,
                                 n_shared_experts=cfg.moe.n_shared_experts)
    if cfg.ssm is not None:
        small["ssm"] = SSMConfig(d_state=16, head_dim=32, expand=2, d_conv=4,
                                 chunk_size=32, n_groups=1)
    if cfg.hybrid_period:
        small["hybrid_period"] = 2
    if cfg.num_encoder_layers:
        small["num_encoder_layers"] = 2
        small["encoder_seq"] = 16
    if cfg.n_image_tokens:
        small["n_image_tokens"] = 8
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
