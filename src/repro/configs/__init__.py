from .base import (ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES,
                   shape_applicable, reduced)
from .archs import ARCHS


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
           "ARCHS", "get_config", "get_shape", "list_archs",
           "shape_applicable", "reduced"]
