"""The 10 assigned architectures, exact configs from the assignment table.

Sources are noted per-arch ([arXiv/hf; tier] as assigned).  Each entry is
importable as ``repro.configs.get_config(<id>)`` and selectable via
``--arch <id>`` in the launchers.
"""
from __future__ import annotations

from .base import ModelConfig, MoEConfig, SSMConfig

# [ssm] SSD (state-space duality) [arXiv:2405.21060; unverified]
MAMBA2_780M = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50280, norm="rms", act="swiglu", attention_free=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4,
                  chunk_size=256, n_groups=1),
)

# [dense] GQA, QKV bias [arXiv:2407.10671; hf]
QWEN2_0_5B = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151936, head_dim=64, qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6,
    remat="dots_nb", attn_block=2048,
)

# [dense] pruned nemotron [arXiv:2407.14679; hf]
MINITRON_8B = ModelConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
    vocab_size=256000, head_dim=128,
    remat="dots_nb", attn_block=2048,
)

# [dense] llama-arch, code, MQA kv=1 [arXiv:2405.04324; hf]
GRANITE_34B = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab_size=49152, head_dim=128,
    remat="dots_nb", attn_block=2048,
)

# [dense] MHA [hf:stabilityai/stablelm-2-1_6b; unverified]
STABLELM_3B = ModelConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab_size=50304, head_dim=80, norm="ln",
    remat="dots_nb", attn_block=2048,
)

# [hybrid] Mamba2 + shared attn blocks [arXiv:2411.15242; hf]
ZAMBA2_2_7B = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, head_dim=80, hybrid_period=6,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, d_conv=4,
                  chunk_size=256, n_groups=1),
)

# [moe] 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B(scaled); hf]
QWEN3_MOE_235B = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab_size=151936, head_dim=128, rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536,
                  capacity_factor=1.25),
    optimizer="adafactor",  # DESIGN.md §6: AdamW fp32 state ≈ 3.3 TB > 1-pod HBM budget
    remat="dots_nb", attn_block=2048,
)

# [moe] 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf]
PHI35_MOE_42B = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab_size=32064, head_dim=128, norm="ln",
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400,
                  capacity_factor=1.25),
    remat="dots_nb", attn_block=2048,
)

# [audio] enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified]
WHISPER_MEDIUM = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=51865, head_dim=64, norm="ln", act="gelu",
    num_encoder_layers=24, encoder_seq=1500,
    remat="dots_nb", attn_block=2048,
)

# [vlm] mistral-7b backbone, anyres tiling (stubbed frontend)
# [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
LLAVA_NEXT_MISTRAL_7B = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128, rope_theta=1e6,
    n_image_tokens=2880,  # anyres: 5 tiles x 576 patch tokens
    remat="dots_nb", attn_block=2048,
)

ARCHS = {
    c.name: c for c in [
        MAMBA2_780M, QWEN2_0_5B, MINITRON_8B, GRANITE_34B, STABLELM_3B,
        ZAMBA2_2_7B, QWEN3_MOE_235B, PHI35_MOE_42B, WHISPER_MEDIUM,
        LLAVA_NEXT_MISTRAL_7B,
    ]
}
