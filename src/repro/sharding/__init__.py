from .specs import (Rules, make_rules, resolve, tree_shardings, constrain,
                    use_rules, active_rules)

__all__ = ["Rules", "make_rules", "resolve", "tree_shardings", "constrain",
           "use_rules", "active_rules"]
