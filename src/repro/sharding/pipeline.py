"""Pipeline parallelism over the `pod` axis (GPipe-style).

Cross-pod DCN bandwidth (~25 GB/s/chip) is far below ICI (~200 GB/s/chip
aggregate), so the right multi-pod decomposition for big models is
pipeline stages across pods: only (B_micro, S, D) activations cross the
DCN, once per microbatch per stage boundary, instead of gradient
all-reduces of the full parameter set.

Implementation: ``shard_map`` over the `pod` axis; layer stacks are split
into `n_stages` contiguous stages (params sharded on the stage dim);
microbatches advance through a ``lax.scan`` whose carry rotates stage
outputs with ``ppermute``.  The standard GPipe schedule runs
(n_micro + n_stages - 1) ticks; bubble fraction = (S-1)/(M+S-1).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map

# jax 0.4.x shard_map has no varying-axis type system; pvary is identity
_pvary = getattr(lax, "pvary", lambda x, axis: x)
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipeline_forward(layer_fn: Callable, stage_params, x, *,
                     mesh: Mesh, axis: str = "pod", n_micro: int = 4):
    """Run x through all pipeline stages.

    layer_fn(params_stage, x_micro) -> x_micro : one stage's computation.
    stage_params: pytree with leading stage dim == mesh.shape[axis]
                  (sharded over `axis`).
    x: (B, ...) global batch, B % n_micro == 0.
    Returns y with x's shape — output of the final stage.
    """
    n_stages = mesh.shape[axis]

    def per_pod(params_local, x_local):
        # params_local: stage dim 1 (this pod's stage); x_local: full batch
        params_me = jax.tree.map(lambda a: a[0], params_local)
        stage_id = lax.axis_index(axis)
        b = x_local.shape[0]
        mb = b // n_micro
        micro = x_local.reshape((n_micro, mb) + x_local.shape[1:])
        n_ticks = n_micro + n_stages - 1
        pad = jnp.zeros((n_stages - 1, mb) + x_local.shape[1:],
                        x_local.dtype)
        feed = jnp.concatenate([micro, pad], axis=0)
        outs0 = jnp.zeros_like(feed)

        def tick(carry, t):
            buf, outs = carry     # buf: (mb, ...) activation entering me
            inject = feed[jnp.minimum(t, n_ticks - 1)]
            x_in = jnp.where(stage_id == 0, inject, buf)
            y = layer_fn(params_me, x_in)
            # pass to next stage (ring; last stage's output is collected)
            nxt = lax.ppermute(y, axis,
                               [(i, (i + 1) % n_stages)
                                for i in range(n_stages)])
            out_idx = t - (n_stages - 1)
            idx = jnp.clip(out_idx, 0, feed.shape[0] - 1)
            outs = jnp.where(out_idx >= 0, outs.at[idx].set(y), outs)
            return (nxt, outs), None

        buf0 = jnp.zeros((mb,) + x_local.shape[1:], x_local.dtype)
        # initial carry must already be pod-varying for scan type stability
        buf0 = _pvary(buf0, axis)
        outs0 = _pvary(outs0, axis)
        (_, outs), _ = lax.scan(tick, (buf0, outs0),
                                jnp.arange(n_ticks))
        # outs on the LAST stage holds the final microbatch outputs;
        # broadcast to all pods (masked psum — ppermute needs a bijection)
        outs = lax.psum(jnp.where(stage_id == n_stages - 1, outs, 0.0),
                        axis)
        return outs[:n_micro].reshape(x_local.shape)

    pspec_params = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(per_pod, mesh=mesh,
                     in_specs=(pspec_params, P()),
                     out_specs=P())(stage_params, x)


def pipeline_bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
