"""Logical-axis sharding rules and resolution onto the physical mesh.

Logical axes used by the model spec trees:
  dp      — batch (data parallel), maps to ("pod","data") or ("data",)
  fsdp    — ZeRO-style parameter shard dim
  tp      — tensor-parallel dim (d_ff, ssm d_inner, vocab)
  tp_kv   — attention KV-group dim (G)
  tp_rep  — attention q-replication dim (R = H / G)
  ep      — MoE expert dim
  sp      — activation sequence dim (sequence parallelism / context parallel)
  kv_seq  — decode-time KV-cache sequence dim

Scheme selection per arch (see DESIGN.md §4):
  'tp'  — Megatron-style TP when G or R divides the model-axis size.
  'sp'  — FSDP(+model axis) + sequence parallelism when neither divides
          (qwen2 G=2,R=7; minitron/phi/llava G=8,R=4): weights are sharded
          over both mesh axes for storage, activations over seq; attention
          einsums stay unsharded over heads but balanced over dp×sp.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Tuple[str, ...]]


def _spec_leaf(x):
    return type(x) is tuple or x is None

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar("repro_rules",
                                                         default=None)


def scheme_for(cfg, tp_size: int) -> str:
    if getattr(cfg, "force_scheme", None):
        return cfg.force_scheme
    if cfg.family == "ssm":
        return "tp"
    g, r = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    if g % tp_size == 0 or r % tp_size == 0:
        return "tp"
    return "sp"


def make_rules(cfg, *, multi_pod: bool = False, mode: str = "train",
               tp_size: int = 16, dp_size: Optional[int] = None,
               global_batch: Optional[int] = None) -> Rules:
    dp_axes: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    if dp_size is None:
        dp_size = (2 * 16) if multi_pod else 16
    if global_batch is not None and global_batch % dp_size != 0:
        dp_axes = ()  # tiny-batch decode (e.g. long_500k B=1): replicate batch
    sch = scheme_for(cfg, tp_size)
    g = cfg.n_kv_heads
    r = cfg.n_heads // max(cfg.n_kv_heads, 1)

    rules: Rules = {
        "dp": dp_axes,
        "ep": ("model",),
        "kv_seq": ("model",),
        "vocab": ("model",),
    }
    if sch == "dp":
        # pure data parallelism over every mesh axis: for small models TP
        # buys nothing and each TP psum costs a (B,S,D) all-reduce per
        # layer (EXPERIMENTS.md §Perf, mamba2 iteration 2)
        rules["dp"] = dp_axes + ("model",)
        if global_batch is not None and global_batch % (dp_size * tp_size):
            rules["dp"] = dp_axes
        rules["tp"] = ()
        rules["tp_kv"] = ()
        rules["tp_rep"] = ()
        rules["sp"] = ()
        rules["fsdp"] = ("data",) if mode == "train" else ("model",)
    elif sch == "tp":
        rules["tp"] = ("model",)
        rules["tp_kv"] = ("model",) if g % tp_size == 0 else ()
        rules["tp_rep"] = (("model",) if (g % tp_size != 0
                                          and r % tp_size == 0) else ())
        rules["sp"] = ()
        rules["fsdp"] = ("data",) if mode == "train" else ()
    else:  # 'sp' scheme
        rules["tp"] = ()
        rules["tp_kv"] = ()
        rules["tp_rep"] = ()
        rules["sp"] = ("model",)
        rules["fsdp"] = (("data", "model") if mode == "train"
                         else ("model",))
    # MoE experts always shard over model; expert-internal fsdp dim follows
    # the global fsdp rule (psum over contracting dim, no weight gather).
    return rules


def resolve(logical: Optional[Tuple], rules: Rules) -> P:
    """logical: tuple of logical names / None per dim -> PartitionSpec."""
    if logical is None:
        return P()
    out = []
    used: set = set()
    for name in logical:
        if name is None:
            out.append(None)
            continue
        axes = rules.get(name, ())
        axes = tuple(a for a in axes if a not in used)
        used.update(axes)
        if len(axes) == 0:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


def legalize(pspec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes from any dim they do not divide evenly (jit rejects
    uneven shardings for its arguments)."""
    out = []
    for i, entry in enumerate(pspec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        while axes:
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if shape[i] % size == 0:
                break
            axes = axes[:-1]
        if len(axes) == 0:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def tree_shardings(spec_tree, mesh: Mesh, rules: Rules, abs_tree=None):
    """Map a tree of logical specs to NamedShardings.  If ``abs_tree``
    (matching tree of arrays/ShapeDtypeStructs) is given, every spec is
    legalized against the leaf shape."""
    if abs_tree is None:
        return jax.tree.map(
            lambda spec: NamedSharding(mesh, resolve(spec, rules)),
            spec_tree, is_leaf=_spec_leaf)
    spec_leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_spec_leaf)
    abs_leaves = treedef.flatten_up_to(abs_tree)
    out = []
    for spec, leaf in zip(spec_leaves, abs_leaves):
        ps = resolve(spec, rules)
        shape = getattr(leaf, "shape", ())
        out.append(NamedSharding(mesh, legalize(ps, shape, mesh)))
    return treedef.unflatten(out)


def tree_pspecs(spec_tree, rules: Rules):
    return jax.tree.map(
        lambda spec: resolve(spec, rules),
        spec_tree, is_leaf=_spec_leaf)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules], mesh: Optional[Mesh] = None):
    tok = _ACTIVE.set(None if rules is None else (rules, mesh))
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def active_rules():
    return _ACTIVE.get()


def constrain(x, logical: Tuple):
    """with_sharding_constraint against the active logical rules (no-op when
    no rules are active, e.g. single-device smoke tests)."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    rules, mesh = ctx
    spec = legalize(resolve(logical, rules), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
