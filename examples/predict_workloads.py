"""One platform spec, two applications: predict HPL Rmax *and* LM
train-step time from the same registry entry.

    PYTHONPATH=src python examples/predict_workloads.py
    PYTHONPATH=src python examples/predict_workloads.py --platform syn-torus-fugaku-4k

This is the workload layer's point (DESIGN.md §15): the `tpu-v5e-pod`
entry carries everything both predictors need — chip peak/HBM, ICI
geometry and bandwidths, MPI-stack knobs, the published HPL run — so
"what does this machine do on HPL" and "what does it do training an LM"
are the same one-liner with a different workload name.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.platforms import get_platform
from repro.workloads import get_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--platform", default="tpu-v5e-pod")
    args = ap.parse_args()
    plat = get_platform(args.platform)
    print(f"[workloads] platform {plat.name}: "
          f"{plat.scale.n_ranks} ranks, {plat.fabric.kind} fabric, "
          f"{plat.node.peak_flops/1e12:.0f} TF/chip")

    hpl = get_workload("hpl").predict(plat)
    print(f"[workloads] hpl         : {hpl['tflops']:10.1f} TF "
          f"(exec {hpl['time_s']:.1f} s on the published run geometry)")

    lm = get_workload("transformer").predict(plat)
    print(f"[workloads] transformer : {lm['step_s']*1e3:10.3f} ms/step "
          f"({lm['tokens_per_s']:.3g} tok/s, mfu {lm['mfu']:.3f})")

    # the same what-if, both workloads: double the interconnect
    from repro.core.predict import whatif_grid
    for name in ("hpl", "transformer"):
        row = whatif_grid(get_workload(name), plat,
                          {"link_bw": [2.0]})[0]
        print(f"[workloads] 2x link_bw on {name:11s}: "
              f"{row['speedup']:.3f}x speedup")


if __name__ == "__main__":
    main()
