"""Reproduce paper Table II: predict Frontera + PupMaya HPL Rmax from
their registry specs, on this laptop-class container, in seconds.

    PYTHONPATH=src python examples/simulate_frontera.py

Every machine number (node peak, fabric, grid, Nmax, reported Rmax)
comes from ``repro.platforms`` — change the spec, re-run the prediction.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.fastsim import simulate_hpl_fast
from repro.platforms import get_platform

SYSTEMS = [("frontera", "Frontera (#5)", "4.8 h"),
           ("pupmaya", "PupMaya (#25)", "1.7 h")]


def main():
    print(f"{'system':15s} {'reported':>9s} {'paper sim':>9s} "
          f"{'our sim':>9s} {'our err':>8s} {'exec':>7s} {'sim wall':>9s}")
    for name, label, paper_wall in SYSTEMS:
        plat = get_platform(name)
        cfg = plat.hpl_config()
        prm = plat.fastsim()
        reported = plat.scale.reported_tflops
        paper_pred = plat.scale.paper_pred_tflops
        t0 = time.perf_counter()
        res = simulate_hpl_fast(cfg, prm)
        wall = time.perf_counter() - t0
        err = (res["tflops"] - reported) / reported * 100
        print(f"{label:15s} {reported:8.0f}T {paper_pred:8.0f}T "
              f"{res['tflops']:8.0f}T {err:+7.1f}% "
              f"{res['time_s']/3600:6.2f}h {wall:8.1f}s"
              f"   (paper sim wall: {paper_wall})")


if __name__ == "__main__":
    main()
