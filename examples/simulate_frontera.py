"""Reproduce paper Table II: predict Frontera + PupMaya HPL Rmax from
public configs, on this laptop-class container, in seconds.

    PYTHONPATH=src python examples/simulate_frontera.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.apps.hpl import HPLConfig
from repro.core.fastsim import FastSimParams, simulate_hpl_fast
from repro.core.hardware.node import frontera_node, pupmaya_node

SYSTEMS = [
    ("Frontera (#5)", frontera_node(), 9_282_848, (88, 91), 23516, 22566,
     "4.8 h"),
    ("PupMaya (#25)", pupmaya_node(), 4_748_928, (59, 72), 7484, 7558,
     "1.7 h"),
]


def main():
    print(f"{'system':15s} {'reported':>9s} {'paper sim':>9s} "
          f"{'our sim':>9s} {'our err':>8s} {'exec':>7s} {'sim wall':>9s}")
    for name, node, N, (P, Q), reported, paper_pred, paper_wall in SYSTEMS:
        cfg = HPLConfig(N=N, nb=384, P=P, Q=Q)
        prm = FastSimParams.from_node(node, link_bw=100e9 / 8)
        t0 = time.perf_counter()
        res = simulate_hpl_fast(cfg, prm)
        wall = time.perf_counter() - t0
        err = (res["tflops"] - reported) / reported * 100
        print(f"{name:15s} {reported:8d}T {paper_pred:8d}T "
              f"{res['tflops']:8.0f}T {err:+7.1f}% "
              f"{res['time_s']/3600:6.2f}h {wall:8.1f}s"
              f"   (paper sim wall: {paper_wall})")


if __name__ == "__main__":
    main()
