"""Trace a Frontera DES run: dump a Chrome trace and summarize it.

    PYTHONPATH=src python examples/trace_frontera.py [--smoke]
        [--out trace_frontera.json] [-N 8192] [--nb 128] [-P 4] [-Q 8]

Runs HPL on Frontera's registry spec (CLX-8280 nodes on the HDR
fat-tree) scaled down to a grid the DES chews through in seconds, with
``trace=True``.  Writes Chrome trace-event JSON — drag it into
https://ui.perfetto.dev (or chrome://tracing) to see one track per rank
with panel_fact / panel_bcast / row_swap / trailing_update phases, the
SimMPI collectives under them, and async slices for in-flight messages —
then prints the per-rank compute/comm/idle breakdown and the critical
path extracted from the recorded happens-before graph.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.apps.hpl import HPLSim
from repro.platforms import get_platform
from repro.trace import (collective_breakdown, critical_path,
                         phase_breakdown, rank_breakdown)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (sub-second run)")
    ap.add_argument("--out", default="trace_frontera.json")
    ap.add_argument("-N", type=int, default=None)
    ap.add_argument("--nb", type=int, default=128)
    ap.add_argument("-P", type=int, default=None)
    ap.add_argument("-Q", type=int, default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        N, P, Q = 1024, 2, 4
    else:
        N = args.N if args.N is not None else 8192
        P = args.P if args.P is not None else 4
        Q = args.Q if args.Q is not None else 8

    plat = get_platform("frontera")
    cfg = plat.hpl_config(N=N, nb=args.nb, P=P, Q=Q)
    print(f"tracing HPL N={cfg.N} nb={cfg.nb} grid={cfg.P}x{cfg.Q} "
          f"on {plat.name!r} ...")
    t0 = time.perf_counter()
    res = HPLSim(cfg, plat, trace=True).run()
    wall = time.perf_counter() - t0
    tr = res.trace
    tr.to_chrome_json(args.out)
    bd = rank_breakdown(tr)              # each analysis pass runs once
    cp = critical_path(tr)

    print(f"  simulated {res.time_s*1e3:.2f} ms ({res.gflops:.0f} GF) in "
          f"{wall:.2f}s wall, {res.events} events")
    print(f"  wrote {args.out}: {len(tr.spans)} spans, {len(tr.msgs)} msgs "
          f"-> open in https://ui.perfetto.dev")

    print("\n  where simulated time goes (mean over ranks):")
    for k in ("compute", "comm", "idle"):
        frac = sum(acc[k] for acc in bd.values()) / len(bd) / res.time_s
        print(f"    {k:8s} {frac*100:5.1f}%")
    print("  phases (rank-seconds):")
    for name, sec in sorted(phase_breakdown(tr).items(),
                            key=lambda kv: -kv[1]):
        print(f"    {name:16s} {sec*1e3:8.2f} ms")
    print("  collectives:")
    for name, acc in sorted(collective_breakdown(tr).items(),
                            key=lambda kv: -kv[1]["seconds"]):
        print(f"    {name:16s} {acc['seconds']*1e3:8.2f} ms over "
              f"{acc['calls']} calls")

    print(f"\n  critical path: {cp.length_s*1e3:.2f} ms of "
          f"{cp.makespan_s*1e3:.2f} ms makespan "
          f"({cp.coverage*100:.0f}% explained, {len(cp.spans)} spans)")
    for cat, sec in sorted(cp.by_cat.items(), key=lambda kv: -kv[1]):
        print(f"    on-path {cat:8s} {sec*1e3:8.2f} ms")

    worst = max(bd.items(), key=lambda kv: kv[1]["comm"])
    print(f"  most comm-bound rank: {worst[0]} "
          f"({worst[1]['comm']/worst[1]['total']*100:.0f}% comm)")


if __name__ == "__main__":
    main()
