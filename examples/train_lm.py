"""End-to-end training driver: train a language model on the synthetic
pipeline with checkpoint/restart and straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py                  # ~20M, fast
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is a ~108M-parameter qwen2-family model (d=768, L=10,
vocab 50257) — "train a ~100M model for a few hundred steps" on CPU.

After training, the same model dims are fed through the transformer
*workload* (``repro.workloads``) to predict what one train step would
cost on an accelerator platform (``--platform``, default tpu-v5e-pod).
Every chip/ICI number comes from the platform registry — nothing is
hardcoded here, and the run fails loudly if the legacy constants drift
from the spec.
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import ModelConfig
from repro.train.loop import train

PRESETS = {
    "20m": dict(num_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
                d_ff=1536, vocab_size=16384, head_dim=64),
    "100m": dict(num_layers=10, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab_size=50257, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--platform", default="tpu-v5e-pod",
                    help="registry platform for the step-time prediction")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.preset}", family="dense",
                      qkv_bias=True, tie_embeddings=True, dtype="float32",
                      optimizer="adafactor", **PRESETS[args.preset])
    n = cfg.n_params()
    print(f"[example] {cfg.name}: ~{n/1e6:.0f}M params, "
          f"{args.steps} steps x {args.batch}x{args.seq} tokens")
    res = train(cfg, steps=args.steps, global_batch=args.batch,
                seq_len=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
                ckpt_every=50, log_every=10)
    losses = res["losses"]
    w = min(10, max(len(losses) // 4, 1))
    head = sum(losses[:w]) / w
    tail = sum(losses[-w:]) / w
    print(f"[example] loss {head:.3f} -> {tail:.3f} "
          f"(window-{w} means); median step "
          f"{res['median_step_s']*1e3:.0f} ms; "
          f"checkpoints in {args.ckpt_dir}")
    # single-step losses are noisy at batch 1: compare windowed means
    assert tail < head + 0.05, "loss must not increase (windowed)"

    # what would this step cost on real accelerators?  Same model dims
    # through the workload layer, chip/ICI numbers from the registry.
    from repro.core.simxla import assert_registry_consistent
    from repro.platforms import get_platform
    from repro.workloads import get_workload

    plat = get_platform(args.platform)
    if args.platform == "tpu-v5e-pod":
        assert_registry_consistent(plat)
    wl = get_workload("transformer", num_layers=cfg.num_layers,
                      d_model=cfg.d_model, d_ff=cfg.d_ff,
                      vocab=cfg.vocab_size, seq_len=args.seq,
                      batch_per_replica=args.batch)
    pred = wl.predict(plat)
    print(f"[example] predicted step on {plat.name}: "
          f"{pred['step_s']*1e3:.3f} ms "
          f"({pred['tokens_per_s']:.3g} tok/s, mfu={pred['mfu']:.3f}; "
          f"peak {plat.node.peak_flops/1e12:.0f} TF/chip from the spec)")


if __name__ == "__main__":
    main()
