"""Batched serving driver + simulator-predicted vs measured throughput —
the paper's methodology (predict performance, then check against a real
run) applied to our own serving engine.

    PYTHONPATH=src python examples/serve_batch.py --requests 8
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=args.batch_slots,
                      max_len=args.prompt_len + args.max_new + 1)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    # simulator prediction: per-decode-step flops at measured CPU rate
    from repro.core.calibrate import calibrate
    prof = calibrate(quick=True)
    flops_per_tok = 2.0 * cfg.n_active_params() * args.batch_slots
    pred_step = flops_per_tok / prof.dgemm.eff_flops
    n_steps = args.requests // args.batch_slots * args.max_new
    pred_total = n_steps * pred_step

    t0 = time.perf_counter()
    results = eng.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in results.values())
    print(f"[serve] {len(results)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    if pred_total < 0.05 * dt:
        print(f"[serve] simulator: decode compute is {pred_total*1e3:.2f} ms "
              f"— this reduced model is dispatch-overhead-bound on CPU "
              f"({dt:.2f}s measured), exactly what the prediction says: "
              f"batch harder or serve a bigger model")
    else:
        print(f"[serve] simulator predicted decode-compute {pred_total:.2f}s "
              f"vs measured {dt:.2f}s")


if __name__ == "__main__":
    main()
