"""Predict a TOP500 list end to end: parse -> infer -> one batched sweep.

    PYTHONPATH=src python examples/predict_top500.py [path/to/list.csv]

Uses the vendored June-2020-era sample (51 systems) by default.  Shows
the ranked predicted-vs-published Rmax table, the fitted per-fabric
efficiency factors, and one machine's inference provenance — the audit
trail explaining every heuristic that shaped its spec.
"""
import sys

from repro.top500 import (load_sample, parse_top500, predict_fleet,
                          FleetTuning)


def main() -> None:
    rows = (parse_top500(sys.argv[1]).rows if len(sys.argv) > 1
            else load_sample())
    report = predict_fleet(rows,
                           tuning=FleetTuning(max_ranks=256,
                                              panels_cap=2048))

    print(f"{len(rows)} machines, one compiled sweep "
          f"(bucket {report.bucket}, {report.compiles} compile)\n")
    print(f"{'#':>3} {'machine':42s} {'family':10s} "
          f"{'pred TF':>10} {'publ TF':>10} {'err':>7}")
    for pos, e in enumerate(report.ranked(), 1):
        print(f"{pos:3d} {e.platform.name:42.42s} {e.family:10s} "
              f"{e.calibrated_tflops:10.0f} {e.published_tflops:10.0f} "
              f"{e.rel_err:+7.1%}")

    cal = report.calibration
    print(f"\nheld-out median |err|: {cal.heldout_median_abs_err:.1%} "
          f"({cal.n_train} train / {cal.n_test} test)")
    print("family efficiency factors:",
          {k: round(v, 3) for k, v in sorted(cal.factors.items())})

    e = report.ranked()[0]
    print(f"\nprovenance for {e.platform.name}:")
    for key, val in e.platform.provenance:
        print(f"  {key:16s} {val}")


if __name__ == "__main__":
    main()
