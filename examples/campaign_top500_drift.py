"""Longitudinal TOP500 drift study as one declarative campaign.

    PYTHONPATH=src python examples/campaign_top500_drift.py [--smoke]
        [--limit N] [--journal runs.ndjson] [--markdown]

Runs the campaign layer's first customer end to end: both vendored
TOP500 sample editions (June-2020-era and Nov-2020-era) are ingested,
a Platform is inferred per machine, each edition's fleet is predicted
as ONE forced-bucket batched sweep with per-fabric calibration, every
machine's prediction is journaled as one NDJSON line, and the report
renders

  * the per-edition ranked predicted-vs-published table,
  * per-machine prediction drift between the editions (machines
    matched by their edition-stable slug — Fugaku's expansion and
    Selene's doubling show up as predicted drift tracking published
    drift), and
  * per-fabric calibration-factor drift (did the model's systematic
    bias move between lists?).

The same study is available from the CLI:

    python -m repro.campaign run --edition-study 2020_06 2020_11
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.campaign import (campaign_report, dispatch_counts,
                            edition_study_spec, render_markdown,
                            render_text, run_campaign)
from repro.top500 import FleetTuning


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small proxy grids + top-12 rows per edition")
    ap.add_argument("--limit", type=int, default=0,
                    help="top-N rows per edition (0 = whole sample)")
    ap.add_argument("--journal", default=None,
                    help="append one NDJSON line per machine")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)

    limit = args.limit or (12 if args.smoke else 0)
    tuning = (FleetTuning(max_ranks=256, panels_cap=2048)
              if args.smoke else None)

    spec = edition_study_spec(["2020_06", "2020_11"], limit=limit)
    result = run_campaign(spec, journal=args.journal, tuning=tuning)

    report = campaign_report(result.records)
    render = render_markdown if args.markdown else render_text
    print(render(report), end="")

    meta = result.summary["meta"]
    d = meta["dispatches"]
    print(f"\n[{meta['runs']} machines across 2 editions in "
          f"{meta['wall_s']:.1f}s; {d['fastsim_dispatches']} batched "
          f"sweep dispatch(es), {d['fastsim_compiles']} fresh "
          f"compile(s)"
          + (f"; journal -> {args.journal}" if args.journal else "")
          + "]", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
