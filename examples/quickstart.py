"""Quickstart: the three faces of the framework in ~a minute.

    PYTHONPATH=src python examples/quickstart.py

1. Simulate HPL on a small cluster (the paper's case study) with the DES
   and the fast vectorized simulator.
2. Predict a TOP500 system (Frontera) from public specs.
3. Predict a TPU transformer cell from its compiled dry-run record (if
   experiments/dryrun exists).
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses

from repro.core.apps.hpl import HPLSim
from repro.core.fastsim import simulate_hpl_fast
from repro.platforms import get_platform


def main():
    print("== 1. small-cluster HPL (DES + fastsim) ==")
    plat = get_platform("bdw-local")        # paper Table I machine
    cfg = plat.hpl_config()
    res = HPLSim(cfg, plat).run()
    print(f"  DES: {res.gflops:.0f} GF in {res.time_s:.3f}s simulated "
          f"({res.events} events)")
    fast = simulate_hpl_fast(
        cfg, dataclasses.replace(plat.fastsim(), lookahead=0.0))
    print(f"  fastsim: {fast['gflops']:.0f} GF "
          f"(agreement {abs(1 - fast['time_s']/res.time_s)*100:.1f}%)")

    print("== 2. Frontera (TOP500 #5) prediction ==")
    frontera = get_platform("frontera")
    reported = frontera.scale.reported_tflops
    t0 = time.perf_counter()
    fast = simulate_hpl_fast(frontera.hpl_config(), frontera.fastsim())
    print(f"  predicted {fast['tflops']:.0f} TF vs {reported:,.0f} TF "
          f"reported ({(fast['tflops']-reported)/reported*100:+.1f}%), "
          f"simulated in {time.perf_counter()-t0:.1f}s "
          f"(paper's SystemC: 4.8 h)")

    rec = Path("experiments/dryrun/qwen2-0.5b__train_4k__16x16.json")
    if rec.exists():
        print("== 3. TPU cell prediction (qwen2-0.5b train_4k, 256 chips) ==")
        from repro.core.predict import predict_cell
        p = predict_cell("qwen2-0.5b", "train_4k")
        print(f"  step {p.step_s*1e3:.0f} ms  (compute {p.compute_s*1e3:.0f}"
              f" / memory {p.memory_s*1e3:.0f}"
              f" / collective {p.collective_s*1e3:.0f} ms)")
    else:
        print("== 3. (skipped — run `python -m repro.launch.dryrun --all`) ==")


if __name__ == "__main__":
    main()
