"""Paper §V what-if analysis, both worlds:

    PYTHONPATH=src python examples/whatif_analysis.py

HPL: which upgrade moves Frontera — faster fabric or faster memory?
     (the whole grid runs as ONE batched fastsim program; paper found
     2x fabric buys only +2.6%)
TPU: which upgrade moves a MoE train step — 2x ICI, 2x HBM, or 2x MXU?
FT:  should a 3x-slow chip be evicted mid-run?
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.predict import whatif_grid
from repro.platforms import get_platform


def main():
    print("== HPL: fabric x memory what-if grid (Frontera, one batch) ==")
    plat = get_platform("frontera")
    cfg = plat.hpl_config()
    base = plat.fastsim()
    grid = whatif_grid(cfg, base, {"link_bw": [1.0, 2.0, 4.0],
                                   "mem_bw": [1.0, 1.25]})
    for row in grid:
        print(f"  link_bw x{row['link_bw']:.2f} mem_bw x{row['mem_bw']:.2f}"
              f": {row['tflops']:.0f} TF ({(row['speedup']-1)*100:+.1f}%)")
    x2 = next(r for r in grid if r["link_bw"] == 2.0 and r["mem_bw"] == 1.0)
    print(f"  2x fabric alone: {(x2['speedup']-1)*100:+.1f}% — paper found "
          f"+2.6%: upgrade not worth it")

    rec = Path("experiments/dryrun/qwen3-moe-235b-a22b__train_4k__16x16.json")
    if rec.exists():
        from repro.core.predict import whatif
        print("== TPU: qwen3-moe-235b train_4k on one v5e pod ==")
        for name, kw in [("2x ICI", dict(link_bw_scale=2.0)),
                         ("2x HBM bw", dict(hbm_bw_scale=2.0)),
                         ("2x MXU peak", dict(peak_scale=2.0))]:
            w = whatif("qwen3-moe-235b-a22b", "train_4k", **kw)
            print(f"  {name:12s}: {w['baseline_s']:.2f}s -> "
                  f"{w['whatif_s']:.2f}s ({w['speedup']:.2f}x)")
        from repro.ft.straggler import simulate_straggler_impact
        print("== FT: one 3x-slow chip (qwen2-0.5b train, DES) ==")
        s = simulate_straggler_impact("qwen2-0.5b", "train_4k",
                                      slowdown=3.0)
        print(f"  step {s['baseline_s']:.3f}s -> {s['straggler_s']:.3f}s "
              f"({s['blowup']:.2f}x) — verdict: {s['verdict']}")
    else:
        print("(TPU sections skipped — run repro.launch.dryrun --all first)")


if __name__ == "__main__":
    main()
