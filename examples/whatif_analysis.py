"""Paper §V what-if analysis, both worlds:

    PYTHONPATH=src python examples/whatif_analysis.py

HPL: is a 200 Gb/s fabric worth it for Frontera?  (paper: no, +2.6%)
TPU: which upgrade moves a MoE train step — 2x ICI, 2x HBM, or 2x MXU?
FT:  should a 3x-slow chip be evicted mid-run?
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.apps.hpl import HPLConfig
from repro.core.fastsim import FastSimParams, simulate_hpl_fast
from repro.core.hardware.node import frontera_node


def main():
    print("== HPL: 100 -> 200 Gb/s fabric (Frontera) ==")
    cfg = HPLConfig(N=9_282_848, nb=384, P=88, Q=91)
    node = frontera_node()
    r100 = simulate_hpl_fast(cfg, FastSimParams.from_node(node,
                                                          link_bw=100e9 / 8))
    r200 = simulate_hpl_fast(cfg, FastSimParams.from_node(node,
                                                          link_bw=200e9 / 8))
    gain = (r200["tflops"] / r100["tflops"] - 1) * 100
    print(f"  {r100['tflops']:.0f} -> {r200['tflops']:.0f} TF "
          f"({gain:+.1f}%) — paper found +2.6%: upgrade not worth it")

    rec = Path("experiments/dryrun/qwen3-moe-235b-a22b__train_4k__16x16.json")
    if rec.exists():
        from repro.core.predict import whatif
        print("== TPU: qwen3-moe-235b train_4k on one v5e pod ==")
        for name, kw in [("2x ICI", dict(link_bw_scale=2.0)),
                         ("2x HBM bw", dict(hbm_bw_scale=2.0)),
                         ("2x MXU peak", dict(peak_scale=2.0))]:
            w = whatif("qwen3-moe-235b-a22b", "train_4k", **kw)
            print(f"  {name:12s}: {w['baseline_s']:.2f}s -> "
                  f"{w['whatif_s']:.2f}s ({w['speedup']:.2f}x)")
        from repro.ft.straggler import simulate_straggler_impact
        print("== FT: one 3x-slow chip (qwen2-0.5b train, DES) ==")
        s = simulate_straggler_impact("qwen2-0.5b", "train_4k",
                                      slowdown=3.0)
        print(f"  step {s['baseline_s']:.3f}s -> {s['straggler_s']:.3f}s "
              f"({s['blowup']:.2f}x) — verdict: {s['verdict']}")
    else:
        print("(TPU sections skipped — run repro.launch.dryrun --all first)")


if __name__ == "__main__":
    main()
