"""Observe a serving run: scrape-ready metrics from one mixed wave.

    PYTHONPATH=src python examples/serve_metrics.py [--manifest runs.ndjson]

Pushes one mixed wave — healthy HPL, a faulted (straggler) scenario, a
transformer step, and a breakdown-DES request — through
``PredictionService``, then prints what an operator would see:

  * the Prometheus text exposition (``svc.prometheus()``) — request
    counters, queue-depth peak, wave sizes, per-request latency
    histogram, engine events/s from the breakdown DES;
  * the per-request latency quantiles straight off the registry;
  * one NDJSON run-manifest line (``svc.manifest()``) — the per-run
    artifact the campaign layer aggregates, optionally appended to an
    NDJSON journal with ``--manifest``.

Everything here is the service's own always-on registry: no flags were
passed, and the simulated numbers are bit-identical to a metrics-off
run (pass ``metrics=NULL_METRICS`` to opt out).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.faults import FaultSpec
from repro.serve import PredictionService, WorkloadRequest


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--manifest", default=None, metavar="PATH",
                    help="append the run-manifest line to this NDJSON "
                         "journal")
    args = ap.parse_args(argv)

    svc = PredictionService()
    hpl = dict(N=1536, nb=128, P=2, Q=2, lookahead=0)
    out = svc.predict_batch([
        WorkloadRequest(rid=0, workload="hpl", platform="bdw-local",
                        params=dict(hpl)),
        WorkloadRequest(rid=1, workload="hpl", platform="bdw-local",
                        params=dict(hpl),
                        faults=FaultSpec.straggler(rank=1, slowdown=2.0)),
        WorkloadRequest(rid=2, workload="transformer",
                        platform="tpu-v5e-pod",
                        params={"mesh": (2, 4), "num_layers": 2}),
        WorkloadRequest(rid=3, workload="hpl", platform="bdw-local",
                        params=dict(hpl), breakdown=True),
    ])
    print(f"served {len(out)} predictions "
          f"(healthy {out[0]['time_s']:.3f}s, "
          f"straggler {out[1]['time_s']:.3f}s, "
          f"step {out[2]['step_s'] * 1e3:.2f}ms, "
          f"breakdown phases: "
          f"{sorted(out[3]['breakdown']['phases'])})")

    print("\n--- Prometheus scrape (svc.prometheus()) " + "-" * 24)
    print(svc.prometheus(), end="")

    lat = svc.metrics.histogram("serve.request_latency_s")
    print("--- request latency " + "-" * 45)
    for q in (0.50, 0.95, 0.99):
        print(f"  p{int(q * 100):<3} {lat.quantile(q) * 1e3:8.2f} ms")

    line = (svc.manifest() if args.manifest is None else None)
    if args.manifest:
        from repro.obs import append_manifest
        line = append_manifest(args.manifest, "serve_run",
                               meta={"example": "serve_metrics",
                                     "stats": dict(svc.stats)},
                               metrics=svc.metrics)
        print(f"\n--- manifest line appended to {args.manifest} " + "-" * 12)
    else:
        print("\n--- NDJSON run manifest (svc.manifest()) " + "-" * 24)
    print(line)


if __name__ == "__main__":
    main()
