"""Resilience what-ifs: predict degraded-platform performance before it
happens on the machine (DESIGN.md §16):

    PYTHONPATH=src python examples/whatif_faults.py

1. One declarative ``FaultSpec`` — a straggler chip at 0.5x plus a
   seeded 5% of links at half bandwidth — runs through BOTH backends:
   the event-level DES (with fault spans in the exportable Chrome
   trace) and the batched fastsim, which sweeps a whole degradation
   grid in one compiled program.
2. A fail-stop scenario runs on the DES (peers block, the run reports
   ``failed=True``) and feeds the elastic-restart planner: which
   data-parallel rows to evict and how to re-partition the batch.
3. The hardened PredictionService serves a budgeted breakdown request:
   blow the deadline and the response degrades to the fastsim answer,
   stamped with the reason, instead of timing out the wave.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.faults import FaultSpec
from repro.faults.fastsim import sweep_faults
from repro.ft import restart_plan_for_faults, simulate_fault_impact
from repro.platforms import get_platform
from repro.serve import PredictionService, WorkloadRequest
from repro.workloads import get_workload


def main():
    plat = get_platform("bdw-local")
    wl = get_workload("hpl", N=1536, nb=128, P=2, Q=4, lookahead=0)
    scenario = (FaultSpec.straggler(rank=1, slowdown=2.0, seed=7)
                + FaultSpec.degraded_links(0.05, factor=0.5, seed=7))

    print("== one scenario, two backends (HPL on bdw-local) ==")
    healthy = wl.predict_des(plat)
    des = wl.predict_des(plat, faults=scenario)
    fast = wl.predict(plat, faults=scenario)
    print(f"  healthy DES : {healthy['time_s']:.3f}s")
    print(f"  faulted DES : {des['time_s']:.3f}s "
          f"({des['time_s'] / healthy['time_s']:.2f}x)")
    print(f"  faulted fast: {fast['time_s']:.3f}s "
          f"(closed form, {abs(fast['time_s'] - des['time_s']) / des['time_s'] * 100:.1f}% off the DES)")

    app = wl.des_app(plat, trace=True, faults=scenario)
    app.run()
    out = Path("whatif_faults_trace.json")
    app.engine.trace.to_chrome_json(str(out))
    print(f"  Chrome trace with fault spans -> {out} (ui.perfetto.dev)")

    print("== degradation grid, one compiled sweep ==")
    specs = [FaultSpec.straggler(rank=1, slowdown=s, seed=7)
             + FaultSpec.degraded_links(0.05, factor=f, seed=7)
             for s in (1.5, 2.0, 4.0) for f in (0.75, 0.5)]
    for spec, row in zip(specs, sweep_faults(wl, plat, specs)[1:]):
        s, f = spec.faults[0].factor, spec.faults[1].factor
        print(f"  straggler x{s:.1f}, links x{f:.2f}: "
              f"{row['slowdown_vs_healthy']:.2f}x slower")

    print("== fail-stop -> elastic restart plan (transformer) ==")
    tf = get_workload("transformer", mesh=(2, 4), num_layers=3)
    dead = FaultSpec.fail_stop(rank=5, at=1e-4)
    impact = simulate_fault_impact(tf, "tpu-v5e-pod", dead, des=True)
    print(f"  DES verdict: {impact['verdict']} "
          f"(failed={impact.get('failed', False)}, "
          f"{impact.get('n_finished')}/8 ranks finished)")
    plan = restart_plan_for_faults(dead, global_batch=64, resume_step=1200,
                                   old_mesh=(2, 4))
    print(f"  restart on {plan.new_mesh}: per-device batch "
          f"{plan.per_device_batch_new}; {plan.notes}")

    print("== hardened serving: deadline -> fastsim fallback ==")
    svc = PredictionService()
    res = svc.predict_batch([
        WorkloadRequest(rid=0, workload="transformer",
                        platform="tpu-v5e-pod",
                        params={"mesh": [2, 4], "num_layers": 2},
                        breakdown=True, timeout_s=60.0),
        WorkloadRequest(rid=1, workload="transformer",
                        platform="tpu-v5e-pod",
                        params={"mesh": [4, 8], "num_layers": 8},
                        breakdown=True, timeout_s=1e-6),
    ])
    print(f"  rid 0: breakdown attached={'breakdown' in res[0]}")
    print(f"  rid 1: degraded={res[1].get('degraded', False)} "
          f"({res[1].get('fallback_reason', '')[:60]})")
    print(f"  stats: {svc.stats}")


if __name__ == "__main__":
    main()
